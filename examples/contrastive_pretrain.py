"""End-to-end driver: the full BASIC three-phase recipe (paper §8) on the
synthetic ALIGN+JFT analog, with checkpointing between phases.

  PYTHONPATH=src python examples/contrastive_pretrain.py [--steps 100]
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # re-parse below

from repro.launch.train import run_contrastive, run_pretrain  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=32)
    args_in = ap.parse_args()

    class A:  # args shim shared by the train-launcher entry points
        arch = "basic-s"
        smoke = True
        steps = args_in.steps
        batch = args_in.batch
        micro = 4
        classes = 16
        lr = 2e-3
        seed = 0
        log_every = 20
        ckpt_dir = None

    print("=== phase 1: supervised image-tower pretraining (JFT analog) ===")
    pre = run_pretrain(A)

    print("=== phase 2: frozen-image contrastive (text tower only) ===")
    params = run_contrastive(A, image_tower_init=pre["tower"],
                             train_image=False)

    print("=== phase 3: joint finetune at reduced LR ===")
    A.lr = 5e-4
    A.steps = max(10, args_in.steps // 4)
    run_contrastive(A, image_tower_init=params["image"]["tower"],
                    train_image=True)
    print("done — see launch/train.py for the production CLI")


if __name__ == "__main__":
    main()
