"""Zero-shot serving demo: the ZeroShotService over a briefly-trained BASIC
dual encoder — micro-batched embedding, registry-cached class matrix, and the
fused Pallas similarity→top-k kernel (DESIGN.md §6).

  PYTHONPATH=src python examples/serving_demo.py --smoke

The demo always runs CPU-sized (smoke-variant towers, embed_dim=32; Pallas
interpret mode is auto-detected on CPU). ``--smoke`` shortens the training
loop to 40 steps (120 without it); ``--steps N`` overrides both. The
decode-loop engine demo this file used to hold lives on in
`python -m repro.launch.serve`.
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_dual_variant
from repro.core.gradaccum import contrastive_step
from repro.data import contrastive_batch, load_tokenizer, \
    world_for_tower
from repro.data.synthetic import render_images
from repro.models import dual_encoder as de
from repro.optim import AdaFactorW, apply_updates
from repro.serving import ZeroShotService

ap = argparse.ArgumentParser(
    description="zero-shot serving demo (always CPU-sized; see module "
                "docstring)")
ap.add_argument("--smoke", action="store_true",
                help="shorter demo training loop (40 steps instead of 120)")
ap.add_argument("--steps", type=int, default=None,
                help="explicit training step count (overrides --smoke)")
args = ap.parse_args()
steps = args.steps if args.steps is not None else (40 if args.smoke else 120)

cfg = smoke_dual_variant(get_arch("basic-s"))
rng = np.random.default_rng(0)
world = world_for_tower(rng, cfg.image_tower, n_classes=16, noise=0.2)
tok = load_tokenizer()     # the committed versioned artifact (v1)

print(f"training the dual encoder for {steps} steps ...")
params = de.init_params(cfg, jax.random.key(0))
opt = AdaFactorW()
st = opt.init(params)
enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731


@jax.jit
def step(params, st, batch):
    _, _, g = contrastive_step(enc_i, enc_t, params, batch, 2)
    up, st = opt.update(g, st, params, 2e-3)
    return apply_updates(params, up), st


for _ in range(steps):
    batch, _ = contrastive_batch(world, tok, 24, rng)
    params, st = step(params, st, jax.tree.map(jnp.asarray, batch))

with tempfile.TemporaryDirectory() as registry_dir, \
        ZeroShotService(cfg, params, tok, registry_dir=registry_dir,
                        max_delay_ms=1.0) as svc:
    cls = rng.integers(0, world.n_classes, 12)
    imgs = render_images(world, cls, rng)

    t0 = time.time()
    res = svc.classify(imgs, world.class_names, k=5)
    print(f"\ncold classify (compile + class matrix v{res.version}): "
          f"{time.time()-t0:.2f}s")
    t0 = time.time()
    res = svc.classify(imgs, world.class_names, k=5)
    print(f"warm classify (registry hit):                {time.time()-t0:.3f}s")

    top1 = float(np.mean(res.indices[:, 0] == cls))
    print(f"\ntop-1 {top1:.2f} (chance {1/world.n_classes:.2f}) — sample:")
    for r in range(3):
        truth = world.class_names[int(cls[r])]
        print(f"  truth {truth!r:18s} top-5 {res.top_names(r)}")

    queries = [f"a photo of a {world.class_names[int(c)]}" for c in cls[:4]]
    gallery = svc.embed_images(imgs)
    _, ridx = svc.retrieve(queries, gallery, k=3)
    print("\ntext->image retrieval (gallery = the 12 demo images):")
    for q, row in zip(queries[:2], ridx[:2]):
        print(f"  {q!r} -> gallery rows {row.tolist()}")

    print("\nservice stats:", svc.stats())
