"""Batched serving demo: prefill + decode loop on a reduced llama3.2 config,
plus a state-space (mamba2) engine to show the O(1)-state decode path.

  PYTHONPATH=src python examples/serving_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf
from repro.serving import Engine

for arch in ("llama3.2-1b", "mamba2-130m"):
    cfg = smoke_variant(get_arch(arch))
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, cache_len=128,
                 moe_args={"dispatch": "dense"})
    rng = np.random.default_rng(0)
    prompts = rng.integers(4, cfg.vocab, (4, 12)).astype(np.int32)

    t0 = time.time()
    out = eng.generate(prompts, 24, temperature=0.8, seed=0)
    dt = time.time() - t0
    print(f"[{arch}] {out.size} tokens in {dt:.2f}s "
          f"({out.size/dt:.0f} tok/s incl. compile)")
    print("  sample:", out[0, :12].tolist())
